"""Unified model API over all assigned families.

``init_params`` / ``loss_fn`` / ``prefill`` / ``decode_step`` cover
dense, MoE, SSM (mamba2), hybrid (zamba2: shared attention block every
``attn_every`` mamba layers) and enc-dec (seamless) architectures.

Layer parameters are *stacked* (leading L axis) and bodies run under
``jax.lax.scan`` so compile time and HLO size are depth-independent —
mandatory for 512-device SPMD compiles of 64–81-layer models.

Cross-entropy is computed in sequence chunks (``lax.scan``) so the
[B, S, vocab] logits tensor is never materialized (vocab up to 256k).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import (attention_decode, attention_train,
                                    build_heads, init_attention,
                                    init_kv_cache)
from repro.models.config import AttnKind, Family, ModelConfig
from repro.models.layers import (Param, dense_init, moe_ffn, rms_norm,
                                 swiglu)
from repro.distributed.ctx import constrain
from repro.models.mamba2 import (init_mamba2_layer, init_ssm_state,
                                 mamba2_decode_step, mamba2_forward)

Array = jax.Array
_F32 = jnp.float32

__all__ = ["init_params", "loss_fn", "forward_hidden", "prefill",
           "decode_step", "init_decode_cache", "hybrid_groups"]


# ---------------------------------------------------------------- stacking
def _stack_init(key: Array, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def _init_mlp(key: Array, cfg: ModelConfig, dtype) -> Param:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
        "w_up": dense_init(ks[1], (cfg.d_model, cfg.d_ff), dtype),
        "w_down": dense_init(ks[2], (cfg.d_ff, cfg.d_model), dtype),
    }


def _init_moe(key: Array, cfg: ModelConfig, ep: int, dtype) -> Param:
    Ep = cfg.padded_experts(ep)
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, Ep), dtype),
        "w_gate": dense_init(ks[1], (Ep, cfg.d_model, cfg.expert_d_ff), dtype),
        "w_up": dense_init(ks[2], (Ep, cfg.d_model, cfg.expert_d_ff), dtype),
        "w_down": dense_init(ks[3], (Ep, cfg.expert_d_ff, cfg.d_model), dtype),
    }
    if cfg.shared_d_ff:
        p["shared_gate"] = dense_init(ks[4], (cfg.d_model, cfg.shared_d_ff), dtype)
        p["shared_up"] = dense_init(ks[5], (cfg.d_model, cfg.shared_d_ff), dtype)
        p["shared_down"] = dense_init(ks[6], (cfg.shared_d_ff, cfg.d_model), dtype)
    return p


def _init_attn_block(key: Array, cfg: ModelConfig, tp: int, dtype,
                     cross: bool = False) -> Param:
    ks = jax.random.split(key, 5)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), _F32),
        "attn": init_attention(ks[0], cfg, tp, dtype),
        "ln2": jnp.zeros((cfg.d_model,), _F32),
    }
    if cfg.family == Family.MOE:
        p["mlp"] = _init_moe(ks[1], cfg, tp, dtype)
    else:
        p["mlp"] = _init_mlp(ks[1], cfg, dtype)
    if cross:
        p["ln_x"] = jnp.zeros((cfg.d_model,), _F32)
        p["cross"] = init_attention(ks[2], cfg, tp, dtype)
    return p


def _init_ssm_block(key: Array, cfg: ModelConfig, dtype) -> Param:
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), _F32),
        "mixer": init_mamba2_layer(ks[0], cfg, dtype),
    }


def hybrid_groups(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, remainder) for the zamba2 layout:
    [group_size mamba layers + shared attn block] × n_groups + remainder."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    rem = cfg.n_layers - n_groups * g
    return n_groups, g, rem


def init_params(cfg: ModelConfig, key: Array, tp: int = 1,
                dtype=None) -> Param:
    dtype = dtype or jnp.dtype(cfg.dtype)
    Vp = cfg.padded_vocab()
    ks = jax.random.split(key, 8)
    params: Param = {
        "embed": dense_init(ks[0], (Vp, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), _F32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, Vp), dtype)

    if cfg.family in (Family.DENSE, Family.MOE):
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: _init_attn_block(k, cfg, tp, dtype))
    elif cfg.family == Family.SSM:
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _init_ssm_block(k, cfg, dtype))
    elif cfg.family == Family.HYBRID:
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: _init_ssm_block(k, cfg, dtype))
        params["shared_attn"] = _init_attn_block(ks[3], cfg, tp, dtype)
    elif cfg.family == Family.ENCDEC:
        params["enc_layers"] = _stack_init(
            ks[2], cfg.n_enc_layers,
            lambda k: _init_attn_block(k, cfg, tp, dtype))
        params["layers"] = _stack_init(
            ks[4], cfg.n_layers,
            lambda k: _init_attn_block(k, cfg, tp, dtype, cross=True))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), _F32)
    return params


# ----------------------------------------------------------------- blocks
def _attn_mlp_block(p: Param, h: Array, cfg: ModelConfig, tp: int, *,
                    causal: bool | None = None,
                    enc_out: Array | None = None) -> Array:
    x = constrain(rms_norm(h, p["ln1"], cfg.rms_eps), "gathered")
    h = h + attention_train(p["attn"], x, cfg, tp, causal=causal)
    if enc_out is not None:
        from repro.models.attention import attention_cross
        h = h + attention_cross(
            p["cross"],
            constrain(rms_norm(h, p["ln_x"], cfg.rms_eps), "gathered"),
            enc_out, cfg, tp)
    hn = constrain(rms_norm(h, p["ln2"], cfg.rms_eps), "gathered")
    hn = constrain(hn, "dec_mlp")      # no-op unless decode rules installed
    if cfg.family == Family.MOE:
        h = h + moe_ffn(hn, p["mlp"], cfg, ep=tp)
    else:
        h = h + swiglu(hn, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return h


def _ssm_block(p: Param, h: Array, cfg: ModelConfig) -> Array:
    return h + mamba2_forward(p["mixer"], rms_norm(h, p["ln1"], cfg.rms_eps),
                              cfg)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


# ------------------------------------------------------------ train forward
def forward_hidden(params: Param, cfg: ModelConfig, tokens: Array,
                   tp: int = 1, *, embeds: Array | None = None,
                   enc_embeds: Array | None = None) -> Array:
    """Token ids (or stub embeddings) -> final hidden states [B, S, d]."""
    if embeds is None:
        h = params["embed"][tokens]
    else:
        h = embeds
    h = constrain(h, "act")

    if cfg.family in (Family.DENSE, Family.MOE):
        def body(carry, p_l):
            c = constrain(carry, "act")
            return constrain(_maybe_remat(
                lambda cc: _attn_mlp_block(p_l, cc, cfg, tp),
                cfg)(c), "act"), None
        h, _ = jax.lax.scan(body, h, params["layers"])

    elif cfg.family == Family.SSM:
        def body(carry, p_l):
            c = constrain(carry, "act")
            return constrain(_maybe_remat(
                lambda cc: _ssm_block(p_l, cc, cfg), cfg)(c), "act"), None
        h, _ = jax.lax.scan(body, h, params["layers"])

    elif cfg.family == Family.HYBRID:
        n_groups, g, rem = hybrid_groups(cfg)
        grouped = jax.tree.map(
            lambda x: x[:n_groups * g].reshape(n_groups, g, *x.shape[1:]),
            params["layers"])
        tail = jax.tree.map(lambda x: x[n_groups * g:], params["layers"])
        shared = params["shared_attn"]

        def group_body(carry, p_g):
            def inner(c, p_l):
                blk = _maybe_remat(
                    lambda cc: _ssm_block(p_l, cc, cfg), cfg)
                return constrain(blk(constrain(c, "act")), "act"), None
            c, _ = jax.lax.scan(inner, carry, p_g)
            c = _maybe_remat(
                lambda cc: _attn_mlp_block(shared, cc, cfg, tp), cfg)(c)
            return constrain(c, "act"), None
        h, _ = jax.lax.scan(group_body, h, grouped)
        if rem:
            def tail_body(carry, p_l):
                return _ssm_block(p_l, carry, cfg), None
            h, _ = jax.lax.scan(tail_body, h, tail)

    elif cfg.family == Family.ENCDEC:
        assert enc_embeds is not None, "enc-dec needs encoder stub embeddings"
        e = enc_embeds

        def enc_body(carry, p_l):
            c = constrain(carry, "act")
            return constrain(_maybe_remat(
                lambda cc: _attn_mlp_block(p_l, cc, cfg, tp, causal=False),
                cfg)(c), "act"), None
        e, _ = jax.lax.scan(enc_body, e, params["enc_layers"])
        enc_out = rms_norm(e, params["enc_norm"], cfg.rms_eps)

        def dec_body(carry, p_l):
            c = constrain(carry, "act")
            return constrain(_maybe_remat(
                lambda cc: _attn_mlp_block(p_l, cc, cfg, tp, causal=True,
                                           enc_out=enc_out), cfg)(c),
                "act"), None
        h, _ = jax.lax.scan(dec_body, h, params["layers"])

    return rms_norm(h, params["final_norm"], cfg.rms_eps)


def _lm_head(params: Param, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_cross_entropy(h: Array, lm_head: Array, labels: Array,
                          vocab_real: int, chunk: int = 512) -> Array:
    """Mean CE without materializing [B, S, V]: scan over S chunks.

    labels < 0 are masked.  Padded-vocab logits are masked to -inf.
    """
    B, S, d = h.shape
    Vp = lm_head.shape[-1]
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    vocab_mask = (jnp.arange(Vp) >= vocab_real)

    # rematerialized: per-chunk [B,chunk,V] logits are recomputed in the
    # backward pass instead of being stored for all chunks.
    @jax.checkpoint
    def body(carry, inp):
        h_c, y_c = inp
        logits = jnp.einsum("bsd,dv->bsv", h_c, lm_head,
                            preferred_element_type=_F32)
        logits = jnp.where(vocab_mask[None, None, :], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = (y_c >= 0).astype(_F32)
        ce = (lse - gold) * mask
        n_tok, s_ce = carry
        return (n_tok + mask.sum(), s_ce + ce.sum()), None

    (n_tok, s_ce), _ = jax.lax.scan(body, (jnp.zeros((), _F32),
                                           jnp.zeros((), _F32)), (hc, yc))
    return s_ce / jnp.maximum(n_tok, 1.0)


def loss_fn(params: Param, cfg: ModelConfig, batch: dict,
            tp: int = 1) -> Array:
    """batch: {"tokens": [B,S] int32, "labels": [B,S] int32 (-1 pad),
    optional "enc_embeds": [B,Senc,d]}."""
    h = forward_hidden(params, cfg, batch["tokens"], tp,
                       enc_embeds=batch.get("enc_embeds"))
    return chunked_cross_entropy(h, _lm_head(params, cfg), batch["labels"],
                                 cfg.vocab_size)


# -------------------------------------------------------------------- decode
def _stacked_ssm_state(cfg: ModelConfig, n_layers: int, batch: int) -> dict:
    one = init_ssm_state(cfg, batch)
    return jax.tree.map(
        lambda x: jnp.zeros((n_layers, *x.shape), x.dtype), one)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      tp: int = 1, dtype=None, enc_len: int = 0) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: dict = {"len": jnp.zeros((batch,), jnp.int32)}
    if cfg.family in (Family.DENSE, Family.MOE):
        cache["kv"] = init_kv_cache(cfg, cfg.n_layers, batch, max_len, tp,
                                    dtype)
    elif cfg.family == Family.SSM:
        cache["ssm"] = _stacked_ssm_state(cfg, cfg.n_layers, batch)
    elif cfg.family == Family.HYBRID:
        n_groups, _, _ = hybrid_groups(cfg)
        cache["ssm"] = _stacked_ssm_state(cfg, cfg.n_layers, batch)
        cache["kv"] = init_kv_cache(cfg, n_groups, batch, max_len, tp, dtype)
    elif cfg.family == Family.ENCDEC:
        cache["kv"] = init_kv_cache(cfg, cfg.n_layers, batch, max_len, tp,
                                    dtype)
        hq, hkv = build_heads(cfg, tp)
        cache["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch, enc_len, hkv, cfg.head_dim), dtype)
        cache["cross_v"] = jnp.zeros(
            (cfg.n_layers, batch, enc_len, hkv, cfg.head_dim), dtype)
        cache["enc_len"] = jnp.full((batch,), enc_len, jnp.int32)
    return cache


def _decode_attn_layer(p_l: Param, h: Array, cfg: ModelConfig, tp: int,
                       kv_l: dict, cache_len: Array,
                       cross: tuple | None = None, commit: bool = True):
    a, kv_new = attention_decode(p_l["attn"],
                                 rms_norm(h, p_l["ln1"], cfg.rms_eps),
                                 cfg, tp, kv_l, cache_len,
                                 update_cache=commit)
    h = h + a
    if cross is not None:
        from repro.models.attention import cross_attention_decode
        ck, cv, enc_len = cross
        h = h + cross_attention_decode(
            p_l["cross"], rms_norm(h, p_l["ln_x"], cfg.rms_eps), cfg, tp,
            ck, cv, enc_len)
    hn = rms_norm(h, p_l["ln2"], cfg.rms_eps)
    hn = constrain(hn, "dec_mlp")      # weight-stationary decode MLP (D2)
    if cfg.family == Family.MOE:
        h = h + moe_ffn(hn, p_l["mlp"], cfg, ep=tp)
    else:
        h = h + swiglu(hn, p_l["mlp"]["w_gate"], p_l["mlp"]["w_up"],
                       p_l["mlp"]["w_down"])
    return h, kv_new


def decode_step(params: Param, cfg: ModelConfig, tokens: Array,
                cache: dict, tp: int = 1,
                commit: bool = True) -> tuple[Array, dict]:
    """One greedy decode step.  tokens: [B] int32 -> (next_logits, cache).

    ``commit=False`` (production serve_step): attention caches stay frozen
    (split-KV reads, no in-graph dynamic updates); the returned cache dict
    carries 1-token KV *deltas* [L,B,1,H,D] for the serving loop's separate
    batched commit, and ``len`` is advanced by the committer.  SSM states
    (O(1), elementwise) are always updated in-graph.
    """
    B = tokens.shape[0]
    h = params["embed"][tokens][:, None, :]          # [B,1,d]
    cache_len = cache["len"]
    new_cache = dict(cache)

    if cfg.family in (Family.DENSE, Family.MOE, Family.ENCDEC):
        kv = cache["kv"]
        cross = None

        def body(carry, xs):
            hh = carry
            if cfg.family == Family.ENCDEC:
                p_l, kv_l, ck, cv = xs
                hh, kv_new = _decode_attn_layer(
                    p_l, hh, cfg, tp, kv_l, cache_len,
                    cross=(ck, cv, cache["enc_len"]), commit=commit)
            else:
                p_l, kv_l = xs
                hh, kv_new = _decode_attn_layer(p_l, hh, cfg, tp, kv_l,
                                                cache_len, commit=commit)
            return hh, kv_new

        if cfg.family == Family.ENCDEC:
            xs = (params["layers"], kv, cache["cross_k"], cache["cross_v"])
        else:
            xs = (params["layers"], kv)
        h, kv_updated = jax.lax.scan(body, h, xs)
        new_cache["kv"] = kv_updated

    elif cfg.family == Family.SSM:
        def body(carry, xs):
            p_l, s_l = xs
            x, s_new = mamba2_decode_step(
                p_l["mixer"], rms_norm(carry, p_l["ln1"], cfg.rms_eps), s_l,
                cfg)
            return carry + x, s_new
        h, ssm_updated = jax.lax.scan(body, h, (params["layers"],
                                                cache["ssm"]))
        new_cache["ssm"] = ssm_updated

    elif cfg.family == Family.HYBRID:
        n_groups, g, rem = hybrid_groups(cfg)
        layers = params["layers"]
        grouped = jax.tree.map(
            lambda x: x[:n_groups * g].reshape(n_groups, g, *x.shape[1:]),
            layers)
        tail = jax.tree.map(lambda x: x[n_groups * g:], layers)
        ssm = cache["ssm"]
        ssm_g = jax.tree.map(
            lambda x: x[:n_groups * g].reshape(n_groups, g, *x.shape[1:]), ssm)
        ssm_t = jax.tree.map(lambda x: x[n_groups * g:], ssm)
        shared = params["shared_attn"]

        def group_body(carry, xs):
            p_g, s_g, kv_l = xs

            def inner(c, xs2):
                p_l, s_l = xs2
                x, s_new = mamba2_decode_step(
                    p_l["mixer"], rms_norm(c, p_l["ln1"], cfg.rms_eps), s_l,
                    cfg)
                return c + x, s_new
            c, s_new = jax.lax.scan(inner, carry, (p_g, s_g))
            c, kv_new = _decode_attn_layer(shared, c, cfg, tp, kv_l,
                                           cache_len, commit=commit)
            return c, (s_new, kv_new)

        h, (ssm_g_new, kv_new) = jax.lax.scan(
            group_body, h, (grouped, ssm_g, cache["kv"]))
        if rem:
            def tail_body(c, xs2):
                p_l, s_l = xs2
                x, s_new = mamba2_decode_step(
                    p_l["mixer"], rms_norm(c, p_l["ln1"], cfg.rms_eps), s_l,
                    cfg)
                return c + x, s_new
            h, ssm_t_new = jax.lax.scan(tail_body, h, (tail, ssm_t))
        else:
            ssm_t_new = ssm_t
        new_cache["ssm"] = jax.tree.map(
            lambda a, b: jnp.concatenate(
                [a.reshape(n_groups * g, *a.shape[2:]), b], axis=0),
            ssm_g_new, ssm_t_new)
        new_cache["kv"] = kv_new

    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _lm_head(params, cfg),
                        preferred_element_type=_F32)[:, 0, :]
    vocab_mask = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
    logits = jnp.where(vocab_mask[None, :], -1e30, logits)
    if commit:
        new_cache["len"] = cache_len + 1
    return logits, new_cache


# ------------------------------------------------------------------- prefill
def prefill(params: Param, cfg: ModelConfig, tokens: Array, tp: int = 1,
            *, enc_embeds: Array | None = None) -> Array:
    """Prefill forward returning last-position logits (the serving engine's
    paged cache is filled separately; see repro.serve)."""
    h = forward_hidden(params, cfg, tokens, tp, enc_embeds=enc_embeds)
    logits = jnp.einsum("bd,dv->bv", h[:, -1, :], _lm_head(params, cfg),
                        preferred_element_type=_F32)
    return logits
