"""Attention blocks: GQA / sliding-window / MLA, for train, prefill, decode.

TPU adaptation notes (DESIGN.md §6):
  * GQA KV heads are *repeated* up to the TP degree at build time
    (``cfg.kv_repeat``) so every model shard owns whole KV heads — compute
    is identical (GQA repeats KV per q-head group anyway), KV params/cache
    grow by the repeat factor on kv<tp archs.
  * MLA keeps the latent KV (kv_lora + rope) *replicated* over ``model``
    (it is tiny) and shards q-heads; decode uses the absorbed-matmul
    formulation (q-latent scores) so the 32k-decode never re-expands
    per-head keys.
  * q-head counts not divisible by TP are padded up (minicpm3 40->48);
    padded heads train as ordinary heads (from-scratch config adaptation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import AttnKind, ModelConfig
from repro.models.layers import (Param, apply_rope, blockwise_attention,
                                 decode_attention, dense_init, rms_norm)

Array = jax.Array
_F32 = jnp.float32

__all__ = ["build_heads", "init_attention", "attention_train",
           "attention_decode", "init_kv_cache", "attention_cross",
           "cross_attention_kv", "cross_attention_decode"]


def build_heads(cfg: ModelConfig, tp: int) -> tuple[int, int]:
    """Effective (q_heads, kv_heads) after TP divisibility adaptation.

    KV heads stay at their original count — params shard on the flattened
    (Hkv*head_dim) axis, which divides the TP degree for every assigned
    arch; the q-per-kv grouping is identical in train and decode.  Only
    q-heads are padded (minicpm3 40 -> 48 for 16-way TP).
    """
    hq = cfg.padded_heads(tp)
    if cfg.attn == AttnKind.MLA:
        return hq, hq
    return hq, cfg.n_kv_heads


def init_attention(key: Array, cfg: ModelConfig, tp: int, dtype) -> Param:
    d = cfg.d_model
    hq, hkv = build_heads(cfg, tp)
    ks = jax.random.split(key, 8)
    if cfg.attn == AttnKind.MLA:
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wq_a": dense_init(ks[0], (d, cfg.q_lora_rank), dtype),
            "q_a_norm": jnp.zeros((cfg.q_lora_rank,), _F32),
            "wq_b": dense_init(ks[1], (cfg.q_lora_rank, hq * qk), dtype),
            "wkv_a": dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                                dtype),
            "kv_a_norm": jnp.zeros((cfg.kv_lora_rank,), _F32),
            "wkv_b": dense_init(ks[3], (cfg.kv_lora_rank,
                                        hq * (cfg.qk_nope_dim + cfg.v_head_dim)),
                                dtype),
            "wo": dense_init(ks[4], (hq * cfg.v_head_dim, d), dtype),
        }
        return p
    p = {
        "wq": dense_init(ks[0], (d, hq * cfg.head_dim), dtype),
        "wk": dense_init(ks[1], (d, hkv * cfg.head_dim), dtype),
        "wv": dense_init(ks[2], (d, hkv * cfg.head_dim), dtype),
        "wo": dense_init(ks[3], (hq * cfg.head_dim, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), _F32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), _F32)
    return p


# ------------------------------------------------------------- train/prefill
def _gqa_qkv(p: Param, x: Array, cfg: ModelConfig, positions: Array,
             hq: int, hkv: int):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"],
                   preferred_element_type=_F32).astype(x.dtype)
    k = jnp.einsum("bsd,de->bse", x, p["wk"],
                   preferred_element_type=_F32).astype(x.dtype)
    v = jnp.einsum("bsd,de->bse", x, p["wv"],
                   preferred_element_type=_F32).astype(x.dtype)
    q = q.reshape(B, S, hq, cfg.head_dim)
    k = k.reshape(B, S, hkv, cfg.head_dim)
    v = v.reshape(B, S, hkv, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mla_q(p: Param, x: Array, cfg: ModelConfig, positions: Array, hq: int):
    B, S, _ = x.shape
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    q_lat = rms_norm(
        jnp.einsum("bsd,dr->bsr", x, p["wq_a"],
                   preferred_element_type=_F32).astype(x.dtype),
        p["q_a_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,re->bse", q_lat, p["wq_b"],
                   preferred_element_type=_F32).astype(x.dtype)
    q = q.reshape(B, S, hq, qk)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Param, x: Array, cfg: ModelConfig, positions: Array):
    """Returns (c_kv [B,S,r], k_rope [B,S,rope]) — the MLA 'KV cache'."""
    kv = jnp.einsum("bsd,de->bse", x, p["wkv_a"],
                    preferred_element_type=_F32).astype(x.dtype)
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_a_norm"], cfg.rms_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]     # 1 shared head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _mla_expand(p: Param, c_kv: Array, cfg: ModelConfig, hq: int):
    """Expand latent to per-head (k_nope, v) for the quadratic phase."""
    B, S, _ = c_kv.shape
    kv = jnp.einsum("bsr,re->bse", c_kv, p["wkv_b"],
                    preferred_element_type=_F32).astype(c_kv.dtype)
    kv = kv.reshape(B, S, hq, cfg.qk_nope_dim + cfg.v_head_dim)
    return kv[..., :cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim:]


def attention_train(p: Param, x: Array, cfg: ModelConfig, tp: int,
                    positions: Array | None = None, *,
                    causal: bool | None = None,
                    kv_override: tuple[Array, Array] | None = None,
                    block_q: int = 512, block_kv: int = 512) -> Array:
    """Full-sequence attention (train / prefill).  Returns [B, S, d].

    kv_override: (k, v) from an encoder for cross-attention.
    """
    B, S, _ = x.shape
    hq, hkv = build_heads(cfg, tp)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    causal = cfg.causal if causal is None else causal

    if cfg.attn == AttnKind.MLA:
        q_nope, q_rope = _mla_q(p, x, cfg, positions, hq)
        c_kv, k_rope = _mla_latent(p, x, cfg, positions)
        k_nope, v = _mla_expand(p, c_kv, cfg, hq)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (*k_nope.shape[:3], cfg.qk_rope_dim))],
            axis=-1)
        out = blockwise_attention(q, k, v, causal=causal, window=cfg.window,
                                  block_q=block_q, block_kv=block_kv,
                                  scale=1.0 / np.sqrt(cfg.qk_head_dim))
        out = out.reshape(B, S, hq * cfg.v_head_dim)
    else:
        q, k, v = _gqa_qkv(p, x, cfg, positions, hq, hkv)
        if kv_override is not None:
            k, v = kv_override
        out = blockwise_attention(q, k, v, causal=causal, window=cfg.window,
                                  block_q=block_q, block_kv=block_kv)
        out = out.reshape(B, S, hq * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"],
                      preferred_element_type=_F32).astype(x.dtype)


# ------------------------------------------------------------- cross-attn
def attention_cross(p: Param, x: Array, enc_out: Array, cfg: ModelConfig,
                    tp: int) -> Array:
    """Decoder->encoder cross attention (no RoPE, bidirectional)."""
    B, Sq, _ = x.shape
    hq, hkv = build_heads(cfg, tp)
    q = jnp.einsum("bsd,de->bse", x, p["wq"],
                   preferred_element_type=_F32).astype(x.dtype)
    q = q.reshape(B, Sq, hq, cfg.head_dim)
    k, v = cross_attention_kv(p, enc_out, cfg, tp)
    out = blockwise_attention(q, k, v, causal=False, window=0)
    out = out.reshape(B, Sq, hq * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"],
                      preferred_element_type=_F32).astype(x.dtype)


def cross_attention_kv(p: Param, enc_out: Array, cfg: ModelConfig,
                       tp: int) -> tuple[Array, Array]:
    """Per-decoder-layer cross K/V from encoder output (decode-time cache)."""
    B, Se, _ = enc_out.shape
    _, hkv = build_heads(cfg, tp)
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"],
                   preferred_element_type=_F32).astype(enc_out.dtype)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"],
                   preferred_element_type=_F32).astype(enc_out.dtype)
    return (k.reshape(B, Se, hkv, cfg.head_dim),
            v.reshape(B, Se, hkv, cfg.head_dim))


def cross_attention_decode(p: Param, x: Array, cfg: ModelConfig, tp: int,
                           k_cache: Array, v_cache: Array,
                           enc_len: Array) -> Array:
    B = x.shape[0]
    hq, _ = build_heads(cfg, tp)
    q = jnp.einsum("bsd,de->bse", x, p["wq"],
                   preferred_element_type=_F32).astype(x.dtype)
    q = q.reshape(B, 1, hq, cfg.head_dim)
    out = decode_attention(q, k_cache, v_cache, enc_len)
    out = out.reshape(B, 1, hq * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"],
                      preferred_element_type=_F32).astype(x.dtype)


# --------------------------------------------------------------------- decode
def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                  tp: int, dtype) -> dict:
    hq, hkv = build_heads(cfg, tp)
    if cfg.attn == AttnKind.MLA:
        return {
            "c_kv": jnp.zeros((n_layers, batch, max_len, cfg.kv_lora_rank),
                              dtype),
            "k_rope": jnp.zeros((n_layers, batch, max_len, cfg.qk_rope_dim),
                                dtype),
        }
    # decode caches keep the *original* kv heads (no TP repeat): the cache
    # is sharded over its sequence axis instead (flash-decoding split-KV).
    hkv_dec = cfg.n_kv_heads
    return {
        "k": jnp.zeros((n_layers, batch, max_len, hkv_dec, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((n_layers, batch, max_len, hkv_dec, cfg.head_dim),
                       dtype),
    }


def _merge_lse(att_cache: Array, lse_cache: Array, att_self: Array,
               s_self: Array) -> Array:
    """Exact online-softmax merge of frozen-cache attention with the
    in-flight token: att_* [B,q,H,D] fp32, lse/s [B,H]."""
    lse_all = jnp.logaddexp(lse_cache, s_self)
    w_c = jnp.exp(lse_cache - lse_all)[:, None, :, None]
    w_s = jnp.exp(s_self - lse_all)[:, None, :, None]
    return att_cache * w_c + att_self * w_s


def attention_decode(p: Param, x: Array, cfg: ModelConfig, tp: int,
                     layer_cache: dict, cache_len: Array,
                     *, update_cache: bool = True) -> tuple[Array, dict]:
    """One-token decode. x: [B, 1, d]; layer_cache holds per-layer slices
    (k/v [B, Smax, Hkv, D] or MLA latents).  cache_len: [B] current length.

    ``update_cache=False`` is the production split-KV path (§Perf iter. D1):
    the sequence-sharded cache stays *frozen* (pure gather/partial-softmax —
    no dynamic-update-slice, so GSPMD never all-gathers it); the new token's
    KV is folded in with an exact log-sum-exp merge and returned as a
    1-token delta for the serving loop's separate batched commit.
    """
    B = x.shape[0]
    hq, _ = build_heads(cfg, tp)
    positions = cache_len[:, None]                         # [B,1]

    if cfg.attn == AttnKind.MLA:
        q_nope, q_rope = _mla_q(p, x, cfg, positions, hq)  # [B,1,H,*]
        c_new, kr_new = _mla_latent(p, x, cfg, positions)  # [B,1,r],[B,1,rope]
        c_cache, kr_cache = layer_cache["c_kv"], layer_cache["k_rope"]
        if update_cache:
            c_cache = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0))
            )(c_cache, c_new, cache_len)
            kr_cache = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0))
            )(kr_cache, kr_new, cache_len)
        # absorbed scores: q_lat = q_nope @ W_uk  -> [B,1,H,r]
        r = cfg.kv_lora_rank
        w_uk = p["wkv_b"].reshape(r, hq, cfg.qk_nope_dim + cfg.v_head_dim)
        w_uk, w_uv = w_uk[..., :cfg.qk_nope_dim], w_uk[..., cfg.qk_nope_dim:]
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk,
                           preferred_element_type=_F32)
        scale = 1.0 / np.sqrt(cfg.qk_head_dim)
        s = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                        c_cache.astype(_F32)) +
             jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(_F32),
                        kr_cache.astype(_F32))) * scale
        pos = jnp.arange(c_cache.shape[1])[None, None, None, :]
        limit = (cache_len + 1) if update_cache else cache_len
        valid = pos < limit[:, None, None, None]
        s = jnp.where(valid, s, -1e30)
        prob = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhqk,bkr->bqhr", prob, c_cache.astype(_F32))
        if not update_cache:
            lse = jax.nn.logsumexp(s, axis=-1)[:, :, 0]      # [B,H]
            s_self = (jnp.einsum("bqhr,bqr->bh", q_lat,
                                 c_new.astype(_F32))
                      + jnp.einsum("bqhr,bqr->bh", q_rope.astype(_F32),
                                   kr_new.astype(_F32))) * scale
            o_self = jnp.broadcast_to(c_new.astype(_F32)[:, :, None, :],
                                      o_lat.shape)
            o_lat = _merge_lse(o_lat, lse, o_self, s_self)
        out = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv.astype(_F32))
        out = out.reshape(B, 1, hq * cfg.v_head_dim).astype(x.dtype)
        if update_cache:
            new_cache = {"c_kv": c_cache, "k_rope": kr_cache}
        else:
            new_cache = {"c_kv": c_new, "k_rope": kr_new}   # 1-token delta
    else:
        hkv_dec = cfg.n_kv_heads
        q = jnp.einsum("bsd,de->bse", x, p["wq"],
                       preferred_element_type=_F32).astype(x.dtype)
        q = q.reshape(B, 1, hq, cfg.head_dim)
        k = jnp.einsum("bsd,de->bse", x, p["wk"],
                       preferred_element_type=_F32).astype(x.dtype)
        k = k.reshape(B, 1, hkv_dec, cfg.head_dim)
        v = jnp.einsum("bsd,de->bse", x, p["wv"],
                       preferred_element_type=_F32).astype(x.dtype)
        v = v.reshape(B, 1, hkv_dec, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.rms_eps)
            k = rms_norm(k, p["k_norm"], cfg.rms_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        k_cache, v_cache = layer_cache["k"], layer_cache["v"]
        if update_cache:
            k_cache = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
            )(k_cache, k, cache_len)
            v_cache = jax.vmap(
                lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i, 0, 0))
            )(v_cache, v, cache_len)
            out = decode_attention(q, k_cache, v_cache, cache_len + 1,
                                   window=cfg.window)
            new_cache = {"k": k_cache, "v": v_cache}
        else:
            # frozen-cache split-KV path + exact self-token merge.
            # Grouped-head einsums (NO kv-head repeat): repeating an
            # S-sharded cache forces GSPMD into a full rematerialization
            # all-gather of the whole cache (§Perf iteration D1's refuted
            # first hypothesis / confirmed second) — grouping q-heads keeps
            # the cache sequence-sharded and the softmax partial.
            scale = 1.0 / np.sqrt(cfg.head_dim)
            g = hq // hkv_dec
            q_g = q.reshape(B, 1, hkv_dec, g, cfg.head_dim)
            s = jnp.einsum("bqhgd,bshd->bhgqs", q_g, k_cache,
                           preferred_element_type=_F32) * scale
            pos = jnp.arange(k_cache.shape[1])[None, None, None, None, :]
            valid = pos < cache_len[:, None, None, None, None]
            if cfg.window > 0:
                valid = valid & (pos >= (cache_len - cfg.window
                                         )[:, None, None, None, None])
            s = jnp.where(valid, s, -1e30)
            prob = jax.nn.softmax(s, axis=-1)
            att = jnp.einsum("bhgqs,bshd->bqhgd",
                             prob.astype(v_cache.dtype), v_cache,
                             preferred_element_type=_F32)  # [B,1,hkv,g,D]
            lse = jax.nn.logsumexp(s, axis=-1)[:, :, :, 0]  # [B,hkv,g]
            s_self = jnp.einsum("bqhgd,bqhd->bhg", q_g.astype(_F32),
                                k.astype(_F32)) * scale
            v_self = jnp.broadcast_to(
                v.astype(_F32)[:, :, :, None, :], att.shape)
            lse_all = jnp.logaddexp(lse, s_self)
            w_c = jnp.exp(lse - lse_all)[:, None, :, :, None]
            w_s = jnp.exp(s_self - lse_all)[:, None, :, :, None]
            out = att * w_c + v_self * w_s
            new_cache = {"k": k, "v": v}                    # 1-token delta
        out = out.reshape(B, 1, hq * cfg.head_dim).astype(x.dtype)
    proj = jnp.einsum("bse,ed->bsd", out, p["wo"],
                      preferred_element_type=_F32).astype(x.dtype)
    return proj, new_cache
