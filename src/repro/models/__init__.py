"""Model zoo: dense/MoE/SSM/hybrid/enc-dec LMs for the assigned pool."""
