"""Train-step factory: loss → grads (±microbatch accumulation) → AdamW.

Distribution is carried entirely by pjit in/out shardings
(``repro.distributed.sharding``); the step body is mesh-agnostic.  With a
data-sharded batch, averaging the loss over the global batch makes GSPMD
emit the DP gradient all-reduce automatically; FSDP param gathers come from
the param shardings.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import loss_fn
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(params) -> dict:
    return {"params": params, "opt": init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, tp: int = 1,
                    microbatches: int = 1, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_shardings``: optional param-tree of NamedShardings; constrains
    each gradient leaf to its FSDP shard right where backward produces it,
    so GSPMD emits reduce-scatter instead of full-size all-reduce
    (§Perf iteration T7)."""

    def compute_loss(params, batch):
        return loss_fn(params, cfg, batch, tp)

    def _constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def grads_of(params, batch):
        if microbatches <= 1:
            l, g = jax.value_and_grad(compute_loss)(params, batch)
            return l, _constrain_grads(g)

        def mb_slice(b, i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // microbatches),
                    x.shape[0] // microbatches, axis=0), b)

        def body(carry, i):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(compute_loss)(params, mb_slice(batch, i))
            g = _constrain_grads(g)
            acc_g = jax.tree.map(jnp.add, acc_g, g)
            return (acc_loss + l, acc_g), None

        zeros = _constrain_grads(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (tot_l, tot_g), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros),
            jnp.arange(microbatches))
        inv = 1.0 / microbatches
        return tot_l * inv, jax.tree.map(lambda g: g * inv, tot_g)

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        new_params, new_opt, gnorm = adamw_update(
            grads, state["opt"], opt_cfg, params=state["params"])
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_step": new_opt["count"]}
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step
