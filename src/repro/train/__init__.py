"""Training steps, trainer loop, fault tolerance."""
