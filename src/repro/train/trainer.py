"""Fault-tolerant training driver.

Production posture for 1000+ nodes, exercised at CPU scale in tests:

  * **checkpoint/restart** — async checkpointer at a step cadence; on any
    step exception (a node failure surfaces as one in practice) the driver
    restores the latest complete checkpoint and replays — the synthetic
    data pipeline is counter-keyed so replay is exact.
  * **failure injection** — ``failure_hook(step)`` may raise to simulate a
    node loss; the driver's recovery path is the same code real failures
    take.
  * **straggler mitigation** — per-step wall time is tracked against a
    rolling median; steps beyond ``straggler_factor``× median are counted
    and surfaced (on a real fleet this signal feeds the scheduler to
    re-shard or evict the slow host; here the mitigation action is a hook).
  * **elastic scaling** — checkpoints store logical arrays only; a restore
    onto a different mesh re-shards via target shardings (see
    ``repro.checkpoint``), and the data stream is mesh-independent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore_checkpoint)
from repro.data.lm import SyntheticLM

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    straggler_factor: float = 3.0
    max_restarts: int = 3


class Trainer:
    def __init__(self, train_step: Callable, init_state, data: SyntheticLM,
                 tcfg: TrainerConfig,
                 failure_hook: Callable[[int], None] | None = None,
                 straggler_hook: Callable[[int, float], None] | None = None,
                 shardings=None):
        self.train_step = train_step
        self.state = init_state
        self.data = data
        self.tcfg = tcfg
        self.failure_hook = failure_hook
        self.straggler_hook = straggler_hook
        self.shardings = shardings
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
        self.metrics_log: list[dict] = []
        self.restarts = 0
        self.straggler_steps = 0

    def _current_step(self) -> int:
        return int(np.asarray(self.state["step"]))

    def _maybe_restore(self) -> None:
        step = latest_step(self.tcfg.ckpt_dir)
        if step is not None:
            self.state, _ = restore_checkpoint(
                self.tcfg.ckpt_dir, self.state, step,
                shardings=self.shardings)

    def run(self) -> dict:
        times: list[float] = []
        while self._current_step() < self.tcfg.total_steps:
            step = self._current_step()
            try:
                # time from the top of the step: injected faults and input
                # stalls are exactly what straggler detection must see
                t0 = time.monotonic()
                if self.failure_hook is not None:
                    self.failure_hook(step)
                batch = self.data.batch_at(step)
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                times.append(dt)
                med = float(np.median(times[-32:]))
                if (len(times) > 4
                        and dt > self.tcfg.straggler_factor * med):
                    self.straggler_steps += 1
                    if self.straggler_hook is not None:
                        self.straggler_hook(step, dt / med)
                self.metrics_log.append(
                    {"step": step, "loss": float(np.asarray(metrics["loss"])),
                     "grad_norm": float(np.asarray(metrics["grad_norm"])),
                     "time_s": dt})
                nxt = self._current_step()
                if nxt % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(self.state, nxt)
            except Exception:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                self.ckpt.wait()
                self._maybe_restore()
        self.ckpt.wait()
        return {"final_step": self._current_step(),
                "restarts": self.restarts,
                "straggler_steps": self.straggler_steps,
                "final_loss": (self.metrics_log[-1]["loss"]
                               if self.metrics_log else float("nan"))}
