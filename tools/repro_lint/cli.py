"""Command-line front end: ``python -m tools.repro_lint src tests benchmarks``."""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from tools.repro_lint import rules  # noqa: F401  (populates REGISTRY)
from tools.repro_lint.engine import (REGISTRY, Context, LintResult,
                                     load_modules, run_rules)


def list_rules() -> str:
    lines = []
    for rid in sorted(REGISTRY):
        r = REGISTRY[rid]
        lines.append(f"{r.id} {r.name}: {r.summary}")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST invariant checker for the repo's reproduction "
                    "contracts (device purity, oracle pairing, flag and "
                    "telemetry discipline).")
    ap.add_argument("paths", nargs="*",
                    default=["src", "tests", "benchmarks"],
                    help="files or directories to lint (default: "
                         "src tests benchmarks)")
    ap.add_argument("--root", default=".",
                    help="root that reported paths are relative to")
    ap.add_argument("--select", action="append", metavar="RL00x",
                    help="run only these rule ids (repeatable)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by "
                         "'# repro-lint: disable=...' comments")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(list_rules())
        return 0

    ctx = Context(load_modules(args.paths, root=pathlib.Path(args.root)))
    res: LintResult = run_rules(ctx, select=args.select)

    if args.as_json:
        print(json.dumps(res.to_json(), indent=2))
    else:
        for f in res.findings:
            print(f.render())
        if args.show_suppressed:
            for f in res.suppressed:
                print(f"{f.render()}  [suppressed]")
        status = "clean" if res.ok else f"{len(res.findings)} finding(s)"
        print(f"repro-lint: {res.n_files} files, {status}, "
              f"{len(res.suppressed)} suppressed", file=sys.stderr)
    return 0 if res.ok else 1


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
