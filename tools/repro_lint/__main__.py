from tools.repro_lint.cli import main

raise SystemExit(main())
