"""repro-lint: AST invariant checker for the repo's reproduction contracts.

Usage: ``python -m tools.repro_lint src tests benchmarks`` (exit 1 on any
unsuppressed finding).  Library entry point: :func:`lint_paths`.
"""
from tools.repro_lint.engine import (REGISTRY, Context, Finding,
                                     LintResult, Module, Rule, lint_paths)
from tools.repro_lint import rules as _rules  # noqa: F401  (populates REGISTRY)

__all__ = ["REGISTRY", "Context", "Finding", "LintResult", "Module",
           "Rule", "lint_paths"]
