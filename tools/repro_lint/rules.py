"""The repro-lint rule set (RL001–RL006).

Each rule mechanizes one of the repo's standing reproduction contracts —
see ``tools/repro_lint/README.md`` for the catalog with rationale,
examples and suppression guidance.  Rules are cross-file by design: they
see every linted module at once (:class:`~tools.repro_lint.engine.Context`)
so they can pair ``kernel.py`` against ``ref.py``, trace jit reachability
across modules, and require that flags/counters are exercised by name in
the test corpus.
"""
from __future__ import annotations

import ast
import pathlib

from tools.repro_lint.engine import (Context, Finding, Module, Rule,
                                     register)

# --------------------------------------------------------------------------
# shared AST helpers
# --------------------------------------------------------------------------

_JAX_MODULE_HINTS = ("jax", "lax", "jnp", "pl", "plgpu", "pltpu")

# transform/control-flow entry points and the positional index of every
# argument that becomes a traced callable
_TRACE_BODY_ARGS: dict[str, tuple[int, ...]] = {
    "jit": (0,), "vmap": (0,), "pmap": (0,), "grad": (0,),
    "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
    "fori_loop": (2,), "scan": (0,), "while_loop": (0, 1),
    "cond": (1, 2), "switch": (1,), "map": (0,),
    "associative_scan": (0,), "pallas_call": (0,), "shard_map": (0,),
}
_TRACE_BODY_KWARGS = ("fun", "f", "body_fun", "cond_fun", "true_fun",
                      "false_fun", "kernel")
_LOOP_APIS = ("fori_loop", "scan", "while_loop", "map", "cond", "switch",
              "associative_scan")
_JIT_DECORATORS = ("jit", "vmap", "checkpoint", "remat", "custom_jvp",
                   "custom_vjp", "pallas_call")

_SANITIZER_ATTRS = {"shape", "dtype", "ndim", "size", "sharding",
                    "weak_type", "aval"}
_SYNC_METHODS = {"item", "tolist", "numpy", "copy_to_host"}
_SYNC_BUILTINS = {"float", "int", "bool", "complex"}
_MUTATOR_METHODS = {"append", "extend", "insert", "remove", "pop",
                    "popitem", "clear", "update", "setdefault", "add",
                    "discard", "appendleft", "extendleft"}


def _dotted(e: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    if isinstance(e, ast.Name):
        return e.id
    if isinstance(e, ast.Attribute):
        base = _dotted(e.value)
        return f"{base}.{e.attr}" if base else None
    return None


def _root_name(e: ast.AST) -> str | None:
    """Leftmost Name of an attribute/subscript/call chain."""
    while isinstance(e, (ast.Attribute, ast.Subscript, ast.Call)):
        e = e.func if isinstance(e, ast.Call) else e.value
    return e.id if isinstance(e, ast.Name) else None


class _Aliases:
    """What this module's imports bind: numpy names, jax-ish names."""

    def __init__(self, mod: Module):
        self.np_mods: set[str] = set()     # names bound to the numpy module
        self.np_funcs: set[str] = set()    # names imported from numpy
        self.jax_mods: set[str] = set(_JAX_MODULE_HINTS)
        self.jax_funcs: set[str] = set()   # from jax[...] import jit, ...
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bound = a.asname or a.name.split(".")[0]
                    if a.name.split(".")[0] == "numpy":
                        self.np_mods.add(bound)
                    elif a.name.split(".")[0] == "jax":
                        self.jax_mods.add(a.asname or a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                top = node.module.split(".")[0]
                for a in node.names:
                    bound = a.asname or a.name
                    if top == "numpy":
                        self.np_funcs.add(bound)
                    elif top == "jax":
                        # submodule import (lax, numpy as jnp, pallas as pl)
                        # vs function import (jit, vmap, ...)
                        if a.name in _TRACE_BODY_ARGS or \
                                a.name == "enable_x64":
                            self.jax_funcs.add(bound)
                        else:
                            self.jax_mods.add(bound)

    def is_numpy_call(self, func: ast.AST) -> bool:
        d = _dotted(func)
        if not d:
            return False
        parts = d.split(".")
        return parts[0] in self.np_mods or \
            (len(parts) == 1 and parts[0] in self.np_funcs)

    def is_jaxish(self, func: ast.AST) -> bool:
        d = _dotted(func)
        return bool(d) and d.split(".")[0] in self.jax_mods


def _trace_entry(call: ast.Call, al: _Aliases) -> tuple[str, list[ast.AST]]:
    """('jit', [body exprs]) when ``call`` is a jax trace entry, else ('', [])."""
    d = _dotted(call.func)
    if not d:
        return "", []
    parts = d.split(".")
    api = parts[-1]
    if api not in _TRACE_BODY_ARGS:
        return "", []
    rooted = len(parts) > 1 and parts[0] in al.jax_mods
    bare = len(parts) == 1 and parts[0] in al.jax_funcs
    if not (rooted or bare):
        return "", []
    bodies: list[ast.AST] = []
    for i in _TRACE_BODY_ARGS[api]:
        if i < len(call.args):
            a = call.args[i]
            if api == "switch" and isinstance(a, (ast.List, ast.Tuple)):
                bodies.extend(a.elts)
            else:
                bodies.append(a)
    bodies.extend(kw.value for kw in call.keywords
                  if kw.arg in _TRACE_BODY_KWARGS)
    return api, bodies


class _Scopes:
    """name -> FunctionDef/Lambda resolution along the enclosing-scope chain."""

    def __init__(self, mod: Module):
        self.mod = mod
        # owner scope (nearest enclosing function or the module) of each def
        self.by_scope: dict[ast.AST, dict[str, ast.AST]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.by_scope.setdefault(self._owner(node), {})[node.name] \
                    = node
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Lambda):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self.by_scope.setdefault(self._owner(node), {})[
                            t.id] = node.value

    def _owner(self, node: ast.AST) -> ast.AST:
        p = self.mod.parents.get(node)
        while p is not None and not isinstance(
                p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
            p = self.mod.parents.get(p)
        return p if p is not None else self.mod.tree

    def resolve(self, name: str, at: ast.AST) -> ast.AST | None:
        scope = self._owner(at)
        while scope is not None:
            hit = self.by_scope.get(scope, {}).get(name)
            if hit is not None:
                return hit
            if isinstance(scope, ast.Module):
                return None
            scope = self._owner(scope)
        return None

    def returned_defs(self, factory: ast.AST) -> list[ast.AST]:
        """Inner defs a factory function returns (``jax.jit(_make(...))``)."""
        if not isinstance(factory, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return []
        inner = {n.name: n for n in ast.walk(factory)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n is not factory}
        out = []
        for node in ast.walk(factory):
            if isinstance(node, ast.Return) and node.value is not None:
                if isinstance(node.value, ast.Name) and \
                        node.value.id in inner:
                    out.append(inner[node.value.id])
                elif isinstance(node.value, ast.Lambda):
                    out.append(node.value)
        return out


def _resolve_body(expr: ast.AST, scopes: _Scopes) -> list[ast.AST]:
    """Function nodes a trace-entry body argument may denote."""
    if isinstance(expr, ast.Lambda):
        return [expr]
    if isinstance(expr, ast.Name):
        hit = scopes.resolve(expr.id, expr)
        return [hit] if hit is not None else []
    if isinstance(expr, ast.Call):
        d = _dotted(expr.func) or ""
        if d.split(".")[-1] == "partial" and expr.args:
            return _resolve_body(expr.args[0], scopes)
        # factory pattern: jax.jit(_make_walk(...)) traces what it returns
        if isinstance(expr.func, ast.Name):
            fac = scopes.resolve(expr.func.id, expr)
            if fac is not None:
                return scopes.returned_defs(fac)
    return []


def _fn_params(fn: ast.AST) -> set[str]:
    a = fn.args
    names = [p.arg for p in
             (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def _own_statements(fn: ast.AST):
    """Walk fn's nodes without descending into nested function defs."""
    stack = list(fn.body) if not isinstance(fn, ast.Lambda) else [fn.body]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda)):
                stack.append(child)


def _store_names(target: ast.AST) -> list[str]:
    return [n.id for n in ast.walk(target)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)]


# --------------------------------------------------------------------------
# RL001 — host syncs inside jit-traced code
# --------------------------------------------------------------------------

def _ordered_params(fn: ast.AST) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _static_param_names(keywords, fn: ast.AST) -> set[str]:
    """Params pinned static by static_argnames/static_argnums keywords."""
    out: set[str] = set()
    pos = _ordered_params(fn)
    for kw in keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            elts = ([v] if isinstance(v, ast.Constant)
                    else v.elts if isinstance(v, (ast.Tuple, ast.List))
                    else [])
            out.update(e.value for e in elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, str))
        elif kw.arg == "static_argnums":
            v = kw.value
            elts = ([v] if isinstance(v, ast.Constant)
                    else v.elts if isinstance(v, (ast.Tuple, ast.List))
                    else [])
            for e in elts:
                if isinstance(e, ast.Constant) and \
                        isinstance(e.value, int) and e.value < len(pos):
                    out.add(pos[e.value])
    return out


class _Taint:
    """Forward may-be-traced analysis over one device function body."""

    def __init__(self, fn: ast.AST, al: _Aliases,
                 tainted: set[str] | None = None):
        self.al = al
        self.tainted = (set(tainted) if tainted is not None
                        else _fn_params(fn))
        # two forward passes over the assignments reach a fixpoint for
        # straight-line and loop-carried locals alike
        for _ in range(2):
            for node in _own_statements(fn):
                if isinstance(node, (ast.Assign, ast.AnnAssign,
                                     ast.AugAssign)):
                    value = node.value
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    if value is not None and self.expr(value):
                        for t in targets:
                            self.tainted.update(_store_names(t))
                elif isinstance(node, ast.For) and self.expr(node.iter):
                    self.tainted.update(_store_names(node.target))

    def expr(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _SANITIZER_ATTRS:
                return False
            return self.expr(e.value)
        if isinstance(e, ast.Call):
            d = _dotted(e.func) or ""
            if d in ("len", "range", "enumerate", "isinstance", "print"):
                return False
            if self.al.is_jaxish(e.func):
                return True          # jnp/lax results are traced values
            if self.al.is_numpy_call(e.func):
                return False         # numpy results are host values
            return (any(self.expr(a) for a in e.args)
                    or any(self.expr(k.value) for k in e.keywords)
                    or self.expr(e.func))
        if isinstance(e, (ast.BinOp, ast.BoolOp, ast.UnaryOp, ast.Compare,
                          ast.Subscript, ast.IfExp, ast.Tuple, ast.List,
                          ast.Starred, ast.Slice, ast.FormattedValue,
                          ast.JoinedStr)):
            return any(self.expr(c) for c in ast.iter_child_nodes(e)
                       if isinstance(c, ast.expr))
        return False


@register
class HostSyncInJit(Rule):
    id = "RL001"
    name = "host-sync-in-jit"
    summary = ("no .item()/float()/np.* host syncs on traced values inside "
               "functions reachable from jax.jit / lax control flow in "
               "device-resident modules")

    def run(self, ctx: Context) -> list[Finding]:
        aliases = {m.rel: _Aliases(m) for m in ctx.modules}
        scopes = {m.rel: _Scopes(m) for m in ctx.modules}
        device_mods = [m for m in ctx.modules
                       if m.matches(*ctx.config["device_modules"])]
        # top-level defs of device-pattern modules, for cross-module
        # call-graph propagation (ops.py helpers called from jitted stages)
        global_defs: dict[str, list[tuple[Module, ast.AST]]] = {}
        for m in device_mods:
            for node in m.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    global_defs.setdefault(node.name, []).append((m, node))

        # ---- phase 1: every function node handed to a trace entry.
        # Taint is tracked per parameter so static_argnames (trace-time
        # Python values like tile widths) never count as traced.
        fn_taint: dict[tuple[str, ast.AST], set[str]] = {}
        queue: list[tuple[Module, ast.AST]] = []

        def mark(m: Module, fn: ast.AST,
                 tainted: set[str] | None = None) -> None:
            new = _fn_params(fn) if tainted is None else set(tainted)
            key = (m.rel, fn)
            cur = fn_taint.get(key)
            if cur is None:
                fn_taint[key] = new
                queue.append((m, fn))
            elif not new <= cur:
                cur |= new
                queue.append((m, fn))

        for m in ctx.modules:
            al, sc = aliases[m.rel], scopes[m.rel]
            for node in ast.walk(m.tree):
                if isinstance(node, ast.Call):
                    api, bodies = _trace_entry(node, al)
                    if api:
                        for b in bodies:
                            for fn in _resolve_body(b, sc):
                                static = _static_param_names(
                                    node.keywords, fn)
                                mark(m, fn, _fn_params(fn) - static)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        target = dec.func if isinstance(dec, ast.Call) \
                            else dec
                        d = _dotted(target) or ""
                        if d.split(".")[-1] == "partial" and \
                                isinstance(dec, ast.Call) and dec.args:
                            d = _dotted(dec.args[0]) or ""
                        if d.split(".")[-1] in _JIT_DECORATORS and (
                                d.split(".")[0] in al.jax_mods
                                or d in _JIT_DECORATORS):
                            static = (_static_param_names(
                                dec.keywords, node)
                                if isinstance(dec, ast.Call) else set())
                            mark(m, node, _fn_params(node) - static)

        # ---- phase 2: taint each device function; propagate through
        # calls that receive traced arguments; flag host syncs
        found: dict[tuple, Finding] = {}
        while queue:
            m, fn = queue.pop()
            al, sc = aliases[m.rel], scopes[m.rel]
            taint = _Taint(fn, al, fn_taint[(m.rel, fn)])
            report = m.matches(*ctx.config["device_modules"])
            for node in _own_statements(fn):
                if not isinstance(node, ast.Call):
                    continue
                hit = None
                func = node.func
                if isinstance(func, ast.Attribute) and \
                        func.attr in _SYNC_METHODS and \
                        taint.expr(func.value):
                    hit = (f".{func.attr}() forces a device->host sync on "
                           f"a traced value inside a jit-traced function")
                elif isinstance(func, ast.Name) and \
                        func.id in _SYNC_BUILTINS and \
                        any(taint.expr(a) for a in node.args):
                    hit = (f"{func.id}() concretizes a traced value "
                           f"(host sync) inside a jit-traced function")
                elif al.is_numpy_call(func) and (
                        any(taint.expr(a) for a in node.args)
                        or any(taint.expr(k.value)
                               for k in node.keywords)):
                    hit = (f"numpy call {_dotted(func)}() on a traced "
                           f"value forces a host sync inside a "
                           f"jit-traced function")
                elif (_dotted(func) or "").split(".")[-1] == \
                        "device_get" and \
                        any(taint.expr(a) for a in node.args):
                    hit = ("jax.device_get() inside a jit-traced function "
                           "is a host sync; fetch after dispatch instead")
                if hit and report:
                    f = Finding(self.id, m.rel, node.lineno,
                                node.col_offset, hit)
                    found[(f.path, f.line, f.col, f.message)] = f
                if hit:
                    continue
                # propagation: traced values flowing into a local or
                # device-module function make its body device-resident —
                # only the parameters actually receiving traced values
                # become tainted (static widths/flags stay host values)
                args_tainted = (any(taint.expr(a) for a in node.args)
                                or any(taint.expr(k.value)
                                       for k in node.keywords))
                if not args_tainted:
                    continue
                callees: list[tuple[Module, ast.AST]] = []
                if isinstance(func, ast.Name):
                    local_callee = sc.resolve(func.id, node)
                    if local_callee is not None:
                        callees.append((m, local_callee))
                if not callees:
                    name = (_dotted(func) or "").split(".")[-1]
                    callees.extend(global_defs.get(name, ()))
                for cm, cfn in callees:
                    mark(cm, cfn, self._call_site_taint(node, cfn, taint))
        return list(found.values())

    @staticmethod
    def _call_site_taint(call: ast.Call, callee: ast.AST,
                         taint: "_Taint") -> set[str]:
        """Callee params that receive traced values at this call site."""
        pos = _ordered_params(callee)
        out: set[str] = set()
        for i, a in enumerate(call.args):
            if isinstance(a, ast.Starred):
                return _fn_params(callee)       # can't track the unpack
            if taint.expr(a) and i < len(pos):
                out.add(pos[i])
        for kw in call.keywords:
            if taint.expr(kw.value):
                if kw.arg is None:              # **kwargs splat
                    return _fn_params(callee)
                out.add(kw.arg)
        return out


# --------------------------------------------------------------------------
# RL002 — kernel / ref-oracle / differential-test triad
# --------------------------------------------------------------------------

def _public_symbols(mod: Module) -> list[tuple[str, int]]:
    """(name, line) of the module's public API (__all__ wins)."""
    def_lines = {n.name: n.lineno for n in mod.tree.body
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef))}
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets):
            if isinstance(node.value, (ast.List, ast.Tuple)):
                return [(e.value, def_lines.get(e.value, node.lineno))
                        for e in node.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)]
    return [(n, ln) for n, ln in def_lines.items()
            if not n.startswith("_")]


_KERNEL_SUFFIXES = ("_scan", "_kernel", "_op", "_device")


def _pair_ref(kernel_name: str, ref_names: list[str]) -> str | None:
    """Best ref.py oracle for a kernel symbol, by normalized-name overlap."""
    nk = kernel_name
    for suf in _KERNEL_SUFFIXES:
        if nk.endswith(suf):
            nk = nk[: -len(suf)]
            break
    best, best_score = None, None
    for r in ref_names:
        nr = r[:-4] if r.endswith("_ref") else r
        cands = [(a, b) for a in (kernel_name, nk) for b in (nr,)]
        if not any(a == b or a in b or b in a for a, b in cands):
            continue
        score = (0 if nk == nr or kernel_name == nr else 1,
                 abs(len(nr) - len(nk)))
        if best_score is None or score < best_score:
            best, best_score = r, score
    return best


@register
class KernelTriad(Rule):
    id = "RL002"
    name = "kernel-triad"
    summary = ("every kernels/<name>/kernel.py public symbol needs a "
               "matching ref.py oracle and a test importing both")

    def run(self, ctx: Context) -> list[Finding]:
        by_rel = {m.rel: m for m in ctx.modules}
        findings = []
        for kmod in ctx.modules:
            if not kmod.matches(*ctx.config["kernel_modules"]):
                continue
            pkg = str(pathlib.PurePosixPath(kmod.rel).parent)
            ref = by_rel.get(f"{pkg}/ref.py")
            if ref is None:
                findings.append(Finding(
                    self.id, kmod.rel, 1, 0,
                    f"kernel package {pkg} has no ref.py oracle module"))
                continue
            ref_names = [n for n, _ in _public_symbols(ref)]
            for name, line in _public_symbols(kmod):
                mate = _pair_ref(name, ref_names)
                if mate is None:
                    findings.append(Finding(
                        self.id, kmod.rel, line, 0,
                        f"kernel symbol {name!r} has no matching oracle "
                        f"in {pkg}/ref.py (expected a *_ref counterpart)"))
                    continue
                tests = ctx.test_modules
                if tests and not any(
                        t.source.find(name) != -1
                        and t.source.find(mate) != -1 for t in tests):
                    findings.append(Finding(
                        self.id, kmod.rel, line, 0,
                        f"no single test module references both kernel "
                        f"{name!r} and its oracle {mate!r} (differential "
                        f"coverage required)"))
        return findings


# --------------------------------------------------------------------------
# RL003 — feature flags default off / to the host value, and are tested
# --------------------------------------------------------------------------

def _kwarg_defaults(fn: ast.AST):
    """(arg, default) pairs for every defaulted parameter."""
    a = fn.args
    pos = [*a.posonlyargs, *a.args]
    for arg, dflt in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        yield arg, dflt
    for arg, dflt in zip(a.kwonlyargs, a.kw_defaults):
        if dflt is not None:
            yield arg, dflt


@register
class DefaultOffFlags(Rule):
    id = "RL003"
    name = "default-off-flags"
    summary = ("bool/enum kwargs on the contract surfaces must default to "
               "the off/host value and be named in a bit-identity test")

    def run(self, ctx: Context) -> list[Finding]:
        targets = set(ctx.config["flag_functions"])
        enum_defaults: dict = ctx.config["enum_defaults"]
        findings = []
        for m in ctx.modules:
            for fn, qual in self._targets(m, targets):
                for arg, dflt in _kwarg_defaults(fn):
                    if arg.arg == "self":
                        continue
                    is_bool = (isinstance(dflt, ast.Constant)
                               and isinstance(dflt.value, bool))
                    is_enum = arg.arg in enum_defaults
                    if not (is_bool or is_enum):
                        continue
                    if is_bool and dflt.value is not False:
                        findings.append(Finding(
                            self.id, m.rel, arg.lineno, arg.col_offset,
                            f"flag {arg.arg!r} on {qual} must default to "
                            f"False (features ship off; the on-path is "
                            f"opt-in)"))
                    if is_enum and not (
                            isinstance(dflt, ast.Constant)
                            and dflt.value == enum_defaults[arg.arg]):
                        findings.append(Finding(
                            self.id, m.rel, arg.lineno, arg.col_offset,
                            f"enum kwarg {arg.arg!r} on {qual} must "
                            f"default to {enum_defaults[arg.arg]!r} "
                            f"(the host/reference engine)"))
                    if ctx.tests_corpus is not None and \
                            not ctx.named_in_tests(arg.arg):
                        findings.append(Finding(
                            self.id, m.rel, arg.lineno, arg.col_offset,
                            f"flag {arg.arg!r} on {qual} is not named in "
                            f"any test (a bit-identity test must pin the "
                            f"off-path)"))
        return findings

    @staticmethod
    def _targets(m: Module, targets: set[str]):
        for node in m.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in targets:
                yield node, node.name
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)) and \
                            f"{node.name}.{sub.name}" in targets:
                        yield sub, f"{node.name}.{sub.name}"


# --------------------------------------------------------------------------
# RL004 — telemetry counters reach summary() and a test assertion
# --------------------------------------------------------------------------

@register
class CounterRegistration(Rule):
    id = "RL004"
    name = "counter-registration"
    summary = ("telemetry counters incremented on a summary()-bearing "
               "class must appear in summary() and a test assertion")

    def run(self, ctx: Context) -> list[Finding]:
        vocab = ctx.config["counter_vocab"]
        findings = []
        for m in ctx.modules:
            if m in ctx.test_modules:
                continue
            for cls in ast.walk(m.tree):
                if not isinstance(cls, ast.ClassDef):
                    continue
                summary_fn = next(
                    (n for n in cls.body
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))
                     and n.name == "summary"), None)
                if summary_fn is None:
                    continue
                counters = self._counters(cls, vocab)
                keys = {k.value for n in ast.walk(summary_fn)
                        if isinstance(n, ast.Dict)
                        for k in n.keys
                        if isinstance(k, ast.Constant)
                        and isinstance(k.value, str)}
                for name, line in sorted(self._increments(cls).items()):
                    if name not in counters:
                        continue
                    if name not in keys:
                        findings.append(Finding(
                            self.id, m.rel, line, 0,
                            f"counter {name!r} is incremented but missing "
                            f"from {cls.name}.summary() (telemetry must "
                            f"surface)"))
                    if ctx.tests_corpus is not None and \
                            not ctx.named_in_tests(name):
                        findings.append(Finding(
                            self.id, m.rel, line, 0,
                            f"counter {name!r} has no test assertion "
                            f"(an increment test must pin it)"))
        return findings

    @staticmethod
    def _counters(cls: ast.ClassDef, vocab) -> set[str]:
        init = next((n for n in cls.body
                     if isinstance(n, ast.FunctionDef)
                     and n.name == "__init__"), None)
        out: set[str] = set()
        if init is None:
            return out
        for node in ast.walk(init):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Constant) and \
                    type(node.value.value) is int:
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self" and \
                            not t.attr.startswith("_") and \
                            any(tok in t.attr.split("_")
                                for tok in vocab):
                        out.add(t.attr)
        return out

    @staticmethod
    def _increments(cls: ast.ClassDef) -> dict[str, int]:
        out: dict[str, int] = {}
        for node in ast.walk(cls):
            if isinstance(node, ast.AugAssign) and \
                    isinstance(node.target, ast.Attribute) and \
                    isinstance(node.target.value, ast.Name) and \
                    node.target.value.id == "self":
                out.setdefault(node.target.attr, node.lineno)
        return out


# --------------------------------------------------------------------------
# RL005 — x64 stays scoped
# --------------------------------------------------------------------------

@register
class X64Scoping(Rule):
    id = "RL005"
    name = "x64-scoping"
    summary = ("enable_x64 only via the scoped context manager; never "
               "module-level jax.config mutation")

    def run(self, ctx: Context) -> list[Finding]:
        findings = []
        for m in ctx.modules:
            for node in ast.walk(m.tree):
                if isinstance(node, ast.Call):
                    d = _dotted(node.func) or ""
                    if d.endswith(".update") and "config" in d and any(
                            isinstance(a, ast.Constant)
                            and isinstance(a.value, str)
                            and "x64" in a.value
                            for a in node.args):
                        findings.append(Finding(
                            self.id, m.rel, node.lineno, node.col_offset,
                            "global jax.config x64 mutation leaks into "
                            "every caller; use the scoped enable_x64 "
                            "context manager"))
                    elif d.split(".")[-1] == "enable_x64":
                        parent = m.parents.get(node)
                        ok = isinstance(parent, (ast.withitem, ast.Return))
                        if not ok:
                            findings.append(Finding(
                                self.id, m.rel, node.lineno,
                                node.col_offset,
                                "enable_x64() must be entered as a scoped "
                                "context manager (with-block or returned "
                                "from the _x64 helper), not called for "
                                "effect"))
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Attribute) and \
                                t.attr == "jax_enable_x64":
                            findings.append(Finding(
                                self.id, m.rel, node.lineno,
                                node.col_offset,
                                "module-level jax_enable_x64 assignment "
                                "is a process-global mutation; use the "
                                "scoped context manager"))
        return findings


# --------------------------------------------------------------------------
# RL006 — loop-body carry purity
# --------------------------------------------------------------------------

def _passes_through_at(e: ast.AST) -> bool:
    """True for jax functional updates: x.at[i].add(v) chains are pure."""
    while isinstance(e, (ast.Attribute, ast.Subscript, ast.Call)):
        if isinstance(e, ast.Attribute) and e.attr == "at":
            return True
        e = e.func if isinstance(e, ast.Call) else e.value
    return False


@register
class LoopCarryPurity(Rule):
    id = "RL006"
    name = "loop-carry-purity"
    summary = ("lax.fori_loop / lax.scan bodies must not close over and "
               "mutate Python state (the double-buffering staleness race)")

    def run(self, ctx: Context) -> list[Finding]:
        findings = []
        for m in ctx.modules:
            if m in ctx.test_modules:
                continue
            al, sc = _Aliases(m), _Scopes(m)
            seen: set[ast.AST] = set()
            for node in ast.walk(m.tree):
                if not isinstance(node, ast.Call):
                    continue
                api, bodies = _trace_entry(node, al)
                if api not in _LOOP_APIS:
                    continue
                for b in bodies:
                    for fn in _resolve_body(b, sc):
                        if fn not in seen:
                            seen.add(fn)
                            findings.extend(self._check(m, fn, api))
        return findings

    def _check(self, m: Module, fn: ast.AST, api: str) -> list[Finding]:
        local = _fn_params(fn)
        if not isinstance(fn, ast.Lambda):
            for node in _own_statements(fn):
                if isinstance(node, (ast.Assign, ast.AugAssign,
                                     ast.AnnAssign)):
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        if isinstance(t, ast.Name):
                            local.add(t.id)
                elif isinstance(node, ast.For):
                    local.update(_store_names(node.target))
        out = []
        for node in _own_statements(fn):
            if isinstance(node, (ast.Nonlocal, ast.Global)):
                kind = ("nonlocal" if isinstance(node, ast.Nonlocal)
                        else "global")
                out.append(Finding(
                    self.id, m.rel, node.lineno, node.col_offset,
                    f"lax.{api} body rebinds enclosing Python state via "
                    f"{kind} — the body runs at trace time only, so the "
                    f"mutation is silently stale"))
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _MUTATOR_METHODS and \
                    not _passes_through_at(node.func) and \
                    _root_name(node.func.value) not in local and \
                    _root_name(node.func.value) is not None:
                out.append(Finding(
                    self.id, m.rel, node.lineno, node.col_offset,
                    f"lax.{api} body mutates closed-over "
                    f"{_root_name(node.func.value)!r} via "
                    f".{node.func.attr}() — trace-time-only effect "
                    f"(silent staleness under double buffering)"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Subscript):
                        root = _root_name(t.value)
                        if root is not None and root not in local:
                            out.append(Finding(
                                self.id, m.rel, node.lineno,
                                node.col_offset,
                                f"lax.{api} body writes into closed-over "
                                f"container {root!r} — trace-time-only "
                                f"effect (silent staleness)"))
        return out
