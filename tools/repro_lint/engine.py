"""repro-lint core: module loading, rule registry, suppressions, reporting.

The checker is a plain ``ast`` pass (stdlib only, no runtime imports of the
linted code): every file is parsed once into a :class:`Module`, all parsed
modules form a :class:`Context`, and each registered :class:`Rule` walks
whatever slice of that context its contract concerns.  Rules may be
cross-file (the kernel-triad rule pairs ``kernel.py`` against ``ref.py``
and the test corpus; the flag/counter rules grep the test corpus for the
names they police) — which is exactly what a per-file linter like ruff
cannot express and why this pass exists.

Suppressions: ``# repro-lint: disable=RL001`` (or a comma list) on the
flagged line, or on a comment-only line immediately above it, silences
those rule ids for that line.  Suppressed findings are counted but do not
fail the run; the CLI can print them with ``--show-suppressed``.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str           # path as reported (relative to the lint root)
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Module:
    """One parsed source file plus the lazy per-module analyses."""

    def __init__(self, path: pathlib.Path, rel: str, source: str):
        self.path = path
        self.rel = rel.replace("\\", "/")
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self._suppressions: dict[int, set[str]] | None = None
        self._parents: dict[ast.AST, ast.AST] | None = None

    # ---------------------------------------------------------- suppressions
    @property
    def suppressions(self) -> dict[int, set[str]]:
        """line number -> rule ids disabled on that line."""
        if self._suppressions is None:
            sup: dict[int, set[str]] = {}
            code_lines: set[int] = set()
            try:
                toks = list(tokenize.generate_tokens(
                    io.StringIO(self.source).readline))
            except tokenize.TokenError:
                toks = []
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    m = _SUPPRESS_RE.search(tok.string)
                    if m:
                        ids = {s.strip() for s in m.group(1).split(",")
                               if s.strip()}
                        sup.setdefault(tok.start[0], set()).update(ids)
                elif tok.type not in (tokenize.NL, tokenize.NEWLINE,
                                      tokenize.INDENT, tokenize.DEDENT,
                                      tokenize.ENCODING,
                                      tokenize.ENDMARKER):
                    code_lines.add(tok.start[0])
            # a comment-only suppression line also covers the next line
            for ln in list(sup):
                if ln not in code_lines:
                    sup.setdefault(ln + 1, set()).update(sup[ln])
            self._suppressions = sup
        return self._suppressions

    def suppressed(self, rule_id: str, line: int) -> bool:
        return rule_id in self.suppressions.get(line, ())

    # --------------------------------------------------------------- parents
    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def matches(self, *patterns: str) -> bool:
        """Right-anchored path match (``kernels/*/ops.py`` style)."""
        p = pathlib.PurePosixPath(self.rel)
        return any(p.match(pat) for pat in patterns)


DEFAULT_CONFIG: dict = {
    # RL001: modules whose traced functions must stay host-sync free
    "device_modules": ("core/device_pipeline.py",
                       "core/shard_pipeline.py", "kernels/*/ops.py",
                       "kernels/*/kernel.py", "kernels/*/ref.py"),
    # RL002: kernel packages follow the ops/ref/differential-test triad
    "kernel_modules": ("kernels/*/kernel.py",),
    # RL003: functions whose new flags must default off / to the host value
    "flag_functions": ("ECICacheManager.__init__", "analyze_windows",
                       "simulate_many", "greedy_allocate",
                       "DeviceWindowPipeline.__init__"),
    # RL003: enum-valued kwargs and their required conservative default
    "enum_defaults": {"pipeline": "host", "engine": "batch"},
    # RL004: name components that mark an int attribute as telemetry
    "counter_vocab": ("windows", "events", "stepdowns", "quarantines",
                      "retries", "decisions", "failures", "loss",
                      "violations", "fallback", "poisoned", "straggler"),
}


class Context:
    """Everything a rule may look at: all parsed modules + config."""

    def __init__(self, modules: list[Module], config: dict | None = None):
        self.modules = modules
        self.config = dict(DEFAULT_CONFIG)
        if config:
            self.config.update(config)
        self._tests_corpus: str | None = None

    @property
    def test_modules(self) -> list[Module]:
        return [m for m in self.modules
                if pathlib.PurePosixPath(m.rel).name.startswith("test_")]

    @property
    def tests_corpus(self) -> str | None:
        """Concatenated test sources, or None when no tests were linted
        (cross-file checks against the test corpus are skipped then)."""
        if self._tests_corpus is None:
            tests = self.test_modules
            self._tests_corpus = ("\n".join(t.source for t in tests)
                                  if tests else "")
        return self._tests_corpus or None

    def named_in_tests(self, name: str) -> bool:
        corpus = self.tests_corpus
        return corpus is not None and \
            re.search(rf"\b{re.escape(name)}\b", corpus) is not None


class Rule:
    """Base class; subclasses set id/name/summary and implement run()."""

    id: str = ""
    name: str = ""
    summary: str = ""

    def run(self, ctx: Context) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError


REGISTRY: dict[str, Rule] = {}


def register(cls: type[Rule]) -> type[Rule]:
    REGISTRY[cls.id] = cls()
    return cls


# ------------------------------------------------------------------ running
def collect_files(paths: list[str | pathlib.Path]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            out.extend(sorted(f for f in p.rglob("*.py")
                              if "__pycache__" not in f.parts
                              and not any(part.startswith(".")
                                          for part in f.parts)))
        elif p.suffix == ".py":
            out.append(p)
    return out


def load_modules(paths: list[str | pathlib.Path],
                 root: pathlib.Path | None = None) -> list[Module]:
    root = pathlib.Path(root) if root is not None else pathlib.Path.cwd()
    mods = []
    for f in collect_files(paths):
        try:
            rel = str(f.resolve().relative_to(root.resolve()))
        except ValueError:
            rel = str(f)
        mods.append(Module(f, rel, f.read_text()))
    return mods


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]
    suppressed: list[Finding]
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {"ok": self.ok, "files": self.n_files,
                "findings": [f.to_json() for f in self.findings],
                "suppressed": [f.to_json() for f in self.suppressed]}


def run_rules(ctx: Context,
              select: list[str] | None = None) -> LintResult:
    by_rel = {m.rel: m for m in ctx.modules}
    active, suppressed = [], []
    for rid in sorted(REGISTRY):
        if select and rid not in select:
            continue
        for f in REGISTRY[rid].run(ctx):
            mod = by_rel.get(f.path)
            if mod is not None and mod.suppressed(f.rule, f.line):
                suppressed.append(f)
            else:
                active.append(f)
    key = (lambda f: (f.path, f.line, f.col, f.rule))
    return LintResult(sorted(active, key=key), sorted(suppressed, key=key),
                      len(ctx.modules))


def lint_paths(paths: list[str | pathlib.Path],
               root: pathlib.Path | None = None,
               config: dict | None = None,
               select: list[str] | None = None) -> LintResult:
    """Parse ``paths`` recursively and run every registered rule."""
    from tools.repro_lint import rules  # noqa: F401  (registers the rules)
    return run_rules(Context(load_modules(paths, root=root), config),
                     select=select)
